"""Optimizers from scratch (no optax in this environment): AdamW + SGD with
global-norm clipping and warmup-cosine schedule.  The optimizer state
pytree mirrors the param tree, so it inherits the params' FSDPxTP sharding
(sharded optimizer state — ZeRO-style — for free under pjit).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class OptConfig:
    name: str = "adamw"
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: OptConfig, step: Array) -> Array:
    """Linear warmup -> cosine decay to min_lr_frac*lr."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = (step - cfg.warmup_steps) / jnp.maximum(
        cfg.total_steps - cfg.warmup_steps, 1)
    prog = jnp.clip(prog, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params, cfg: OptConfig) -> Dict[str, Any]:
    zeros = lambda: jax.tree.map(jnp.zeros_like, params)
    if cfg.name == "adamw":
        return {"mu": zeros(), "nu": zeros(), "count": jnp.zeros((), jnp.int32)}
    if cfg.name == "sgd":
        return {"mu": zeros(), "count": jnp.zeros((), jnp.int32)}
    raise ValueError(cfg.name)


def clip_by_global_norm(grads, max_norm: float):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads), gn


def apply_updates(params, grads, state: Dict[str, Any], cfg: OptConfig
                  ) -> Tuple[Any, Dict[str, Any], Dict[str, Array]]:
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    count = state["count"] + 1
    lr = schedule(cfg, count)
    if cfg.name == "adamw":
        mu = jax.tree.map(lambda m, g: cfg.b1 * m + (1 - cfg.b1) *
                          g.astype(m.dtype), state["mu"], grads)
        nu = jax.tree.map(lambda v, g: cfg.b2 * v + (1 - cfg.b2) *
                          jnp.square(g.astype(v.dtype)), state["nu"], grads)
        c = count.astype(jnp.float32)
        bc1 = 1 - cfg.b1 ** c
        bc2 = 1 - cfg.b2 ** c

        def upd(p, m, v):
            u = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
            u = u + cfg.weight_decay * p.astype(u.dtype)
            return (p.astype(jnp.float32) - lr * u).astype(p.dtype)

        new_params = jax.tree.map(upd, params, mu, nu)
        return new_params, {"mu": mu, "nu": nu, "count": count}, \
            {"lr": lr, "grad_norm": gnorm}
    # sgd + momentum
    mu = jax.tree.map(lambda m, g: 0.9 * m + g.astype(m.dtype),
                      state["mu"], grads)
    new_params = jax.tree.map(
        lambda p, m: (p.astype(jnp.float32) - lr * m).astype(p.dtype), params, mu)
    return new_params, {"mu": mu, "count": count}, {"lr": lr, "grad_norm": gnorm}
