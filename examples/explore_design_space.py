"""Design-space exploration: the paper's Table-4 comparison as a *search*.

Table 4 compares three hand-picked configurations — the [15] baseline
((8,16) fixed point, per-step ALU), "this work" on DSPs (MXU), and the
DSP-free variant (VPU).  The parameterised design makes that table one
slice of a space: here we sweep compute unit x ALU mode x fixed-point
format through ``repro.explore``, score every point by measured throughput,
modelled GOP/s/W, and int-vs-float fidelity, print the Pareto front, and
let ``autotune`` pick the deployment point under a power constraint —
ending at the same configuration the paper hand-picks ((4,8), pipelined,
step activations) when the constraint allows it.

Run:  PYTHONPATH=src python examples/explore_design_space.py
"""
from repro import explore
from repro.analysis.report import pareto_table
from repro.core.fixed_point import FXP_4_8, FXP_8_16

# The Table-4 axes.  hs_method stays at the paper's 'step' (Table 1 showed
# the three methods are accuracy-equivalent; 'step' is the cheapest) and
# batch at 64 to keep this example CPU-friendly.
space = explore.SearchSpace(
    fxp=(FXP_4_8, FXP_8_16),
    compute_unit=("mxu", "vpu"),
    alu_mode=("pipelined", "per_step"),
    batch=(64,),
)
print(f"sweeping {space.size} configurations "
      f"(Table 4 compared 3 hand-picked ones)...\n")

objectives = dict(explore.DEFAULT_OBJECTIVES, int_float_mse="min")
result = explore.sweep(space, iters=10, objectives=objectives, log=print)

print()
print(pareto_table(result))

# Deployment: maximise energy efficiency under a power envelope — the
# paper's embedded scenario (its whole board draws ~0.76 W; our TPU energy
# model's static floor is 60 W, so the cap below is the analogous "fit the
# budget" constraint, not the paper's number).  Reuses the sweep above
# (payload=) instead of re-measuring all points.
session = explore.autotune(
    payload=result,
    objective="gops_per_watt",
    constraints={"total_w": (None, 61.0)},
)
best = session.autotune_summary["best"]
print(f"\n[autotune] deployed point: {best['label']}")
print(f"[autotune] {best['metrics']['samples_per_s']:,.0f} samples/s, "
      f"{best['metrics']['gops_per_watt']:.4f} GOP/s/W "
      f"(paper's FPGA point: 32,873 samples/s, 11.89 GOP/s/W)")
print(f"[autotune] session ready: {session!r}")
