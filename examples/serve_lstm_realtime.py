"""Real-time LSTM inference — the paper's deployment scenario (§6: 32873
samples/s on the XC7S15 at 204 MHz) — through ``Accelerator.serve``.

Streams windows through the int8 accelerator datapath in fixed-size waves
(the jitted engine sees one static shape) and reports samples/s plus the
projected TPU-side GOP/s and GOP/s/W from the energy model.

Run:  PYTHONPATH=src python examples/serve_lstm_realtime.py
"""
import time

import numpy as np

import repro
from repro.core.accelerator import PAPER_DEFAULT, PAPER_NO_MXU
from repro.core.qlstm import QLSTMConfig
from repro.data.timeseries import pems_like_dataset

cfg = QLSTMConfig()
data = pems_like_dataset(seq_len=cfg.seq_len)
x, y = data["test"]

acc = repro.build(cfg, PAPER_DEFAULT, seed=0)
acc.train_qat(data, steps=200, log_every=100).quantize()

BATCH = 256
# Whole waves only, within the test set: no final-wave padding in the clock.
N = (min(BATCH * 20, len(x)) // BATCH) * BATCH
# Warm-up wave compiles the serving datapath once.
next(acc.serve(iter(x[:BATCH]), batch=BATCH))

t0 = time.perf_counter()
preds = list(acc.serve(iter(x[:N]), batch=BATCH))
dt = time.perf_counter() - t0
sps = len(preds) / dt
ops = acc.report()["ops_per_inference"]
print(f"[serve] {len(preds)} samples in {dt:.2f}s = {sps:,.0f} samples/s "
      f"(CPU interpret mode; paper: 32,873 samples/s on FPGA)")
print(f"[serve] equivalent GOP/s at this rate: {sps*ops/1e9:.3f}")
print(f"[serve] stream MSE vs targets: "
      f"{float(np.mean((np.stack(preds) - y[:N]) ** 2)):.5f}")

for name, accel in [("mxu (DSP)", PAPER_DEFAULT), ("vpu (no-DSP)", PAPER_NO_MXU)]:
    # project: TPU latency bound by weight streaming + compute at unit peak
    rep = repro.build(cfg, accel).report(latency_s=BATCH / 32873.0,
                                         batch=BATCH)["energy"]
    print(f"[energy/{name:12s}] GOP/s/W={rep['gops_per_watt']:.2f} "
          f"total_W={rep['total_w']:.1f} (paper: 11.89 GOP/s/W)")
