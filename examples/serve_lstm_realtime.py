"""Real-time LSTM inference — the paper's deployment scenario (§6: 32873
samples/s on the XC7S15 at 204 MHz) — in both serving forms.

Part 1 streams windows through ``Accelerator.serve`` (stateless fixed-size
waves — the jitted engine sees one static shape) and reports samples/s
plus the projected TPU-side GOP/s and GOP/s/W from the energy model.
Part 2 is the production form (docs/SERVING.md): many named sensor
streams multiplexed through ``repro.serving.StreamServer``, each stream's
LSTM (h, c) carried across windows — predictions see the stream's whole
history, not just the current window.

Run:  PYTHONPATH=src python examples/serve_lstm_realtime.py
"""
import time

import numpy as np

import repro
from repro.core.accelerator import PAPER_DEFAULT, PAPER_NO_MXU
from repro.core.qlstm import QLSTMConfig
from repro.data.timeseries import pems_like_dataset
from repro.serving import StreamServer

cfg = QLSTMConfig()
data = pems_like_dataset(seq_len=cfg.seq_len)
x, y = data["test"]

acc = repro.build(cfg, PAPER_DEFAULT, seed=0)
acc.train_qat(data, steps=200, log_every=100).quantize()

BATCH = 256
# Whole waves only, within the test set: no final-wave padding in the clock.
N = (min(BATCH * 20, len(x)) // BATCH) * BATCH
# Warm-up wave compiles the serving datapath once.
next(acc.serve(iter(x[:BATCH]), batch=BATCH))

t0 = time.perf_counter()
preds = list(acc.serve(iter(x[:N]), batch=BATCH))
dt = time.perf_counter() - t0
sps = len(preds) / dt
ops = acc.report()["ops_per_inference"]
print(f"[serve] {len(preds)} samples in {dt:.2f}s = {sps:,.0f} samples/s "
      f"(CPU interpret mode; paper: 32,873 samples/s on FPGA)")
print(f"[serve] equivalent GOP/s at this rate: {sps*ops/1e9:.3f}")
print(f"[serve] stream MSE vs targets: "
      f"{float(np.mean((np.stack(preds) - y[:N]) ** 2)):.5f}")

for name, accel in [("mxu (DSP)", PAPER_DEFAULT), ("vpu (no-DSP)", PAPER_NO_MXU)]:
    # project: TPU latency bound by weight streaming + compute at unit peak
    rep = repro.build(cfg, accel).report(latency_s=BATCH / 32873.0,
                                         batch=BATCH)["energy"]
    print(f"[energy/{name:12s}] GOP/s/W={rep['gops_per_watt']:.2f} "
          f"total_W={rep['total_w']:.1f} (paper: 11.89 GOP/s/W)")

# --- Part 2: multiplexed STATEFUL streams (repro.serving) -------------------
# 16 sensors, 8 windows each; every sensor's (h, c) carries across its
# windows, so window k sees the sensor's whole history — bit-identical to
# running each sensor's concatenated sequence in one shot.
N_STREAMS, N_WINDOWS = 16, 8
with StreamServer(acc, batch=N_STREAMS, deadline_s=0.02,
                  max_streams=N_STREAMS) as server:
    server.submit("warmup", x[0])          # compile outside the clock
    server.drain()
    server.end_stream("warmup")
    server.reset_metrics()
    for w in range(N_WINDOWS):
        for s in range(N_STREAMS):
            server.submit(f"sensor-{s}", x[(s * N_WINDOWS + w) % len(x)])
    server.drain()
    m = server.metrics_summary()
print(f"[stream] {m['samples']} windows over {N_STREAMS} stateful streams: "
      f"{m['samples_per_s']:,.0f} samples/s, "
      f"p50/p95/p99 = {m['latency_ms']['p50']:.1f}/"
      f"{m['latency_ms']['p95']:.1f}/{m['latency_ms']['p99']:.1f} ms")
print(f"[stream] occupancy {m['mean_occupancy']:.1f}/{m['batch']}, "
      f"deadline flushes {m['deadline_flushes']}, "
      f"evictions {m['state']['evictions']}, "
      f"GOP/s/W at measured point {m['gops_per_watt']:.2e}")
