"""Real-time LSTM inference — the paper's deployment scenario (§6: 32873
samples/s on the XC7S15 at 204 MHz).

Streams batched windows through the int8 accelerator datapath (fused Pallas
kernel in interpret mode on CPU) and reports samples/s plus the projected
TPU-side GOP/s and GOP/s/W from the energy model.

Run:  PYTHONPATH=src python examples/serve_lstm_realtime.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.accelerator import PAPER_DEFAULT, PAPER_NO_MXU, plan
from repro.core.energy import power_report
from repro.core.qlstm import QLSTMConfig, ops_per_inference
from repro.data.timeseries import pems_like_dataset
from repro.models import lstm_model

cfg = QLSTMConfig()
data = pems_like_dataset(seq_len=cfg.seq_len)
x, y = data["test"]
params = lstm_model.init_lstm_model(cfg, jax.random.key(0))[0]

BATCH = 256
serve = jax.jit(lambda xb: lstm_model.serve_int(params, xb, cfg, PAPER_DEFAULT))
xb = jnp.asarray(x[:BATCH])
serve(xb).block_until_ready()  # compile

n_batches = 20
t0 = time.perf_counter()
for i in range(n_batches):
    serve(xb).block_until_ready()
dt = time.perf_counter() - t0
sps = BATCH * n_batches / dt
ops = ops_per_inference(cfg)
print(f"[serve] {BATCH*n_batches} samples in {dt:.2f}s = {sps:,.0f} samples/s "
      f"(CPU interpret mode; paper: 32,873 samples/s on FPGA)")
print(f"[serve] equivalent GOP/s at this rate: {sps*ops/1e9:.3f}")

for name, acc in [("mxu (DSP)", PAPER_DEFAULT), ("vpu (no-DSP)", PAPER_NO_MXU)]:
    p = plan(cfg, acc)
    # project: TPU latency bound by weight streaming + compute at unit peak
    rep = power_report(flops=ops * BATCH, hbm_bytes=p["weight_bytes"],
                       ici_bytes=0, latency_s=BATCH / 32873.0,
                       unit=p["compute_unit"], dtype="int8")
    print(f"[energy/{name:12s}] GOP/s/W={rep['gops_per_watt']:.2f} "
          f"total_W={rep['total_w']:.1f} (paper: 11.89 GOP/s/W)")
