"""Batched LM serving demo: greedy decode with KV cache, optionally with
the paper's quantisation applied at LM scale (int8 weights + int8 KV).

Run:  PYTHONPATH=src python examples/serve_lm.py
      PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-7b
      PYTHONPATH=src python examples/serve_lm.py --quant w8 --kv-int8
"""
from repro.launch.serve import main
main()
