"""Quickstart: the paper's pipeline end to end in ~a minute on CPU —
through the unified session API (docs/API.md).

1. ``repro.build`` the paper's accelerator (hidden 20, (4,8) fixed point,
   HardSigmoid* 'step' + HardTanh, pipelined ALU on the MXU).
2. ``train_qat`` briefly on synthetic PeMS-like traffic data.
3. ``quantize`` and run the deployment path — the plan selects the fused
   Pallas kernel (interpret mode on CPU) — and check it matches QAT and is
   bit-identical across every backend engine.
4. ``report()`` the Table-2 accelerator plan and Table-4-style energy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp

import repro
from repro.core.accelerator import PAPER_DEFAULT
from repro.core.qlstm import QLSTMConfig
from repro.data.timeseries import pems_like_dataset

cfg = QLSTMConfig()  # the paper's model
data = pems_like_dataset(seq_len=cfg.seq_len)
xte, yte = data["test"]

acc = repro.build(cfg, PAPER_DEFAULT, seed=0)
acc.train_qat(data, steps=150, batch=64, lr=3e-3).quantize()

x = jnp.asarray(xte[:512])
y = jnp.asarray(yte[:512])
mse_qat = float(jnp.mean((acc.infer(x, path="qat") - y) ** 2))
pred_hw = acc.infer(x, path="int")            # plan-selected Pallas kernel
mse_hw = float(jnp.mean((pred_hw - y) ** 2))
print(f"\ntest MSE: QAT={mse_qat:.5f}  int8-accelerator={mse_hw:.5f} "
      f"(paper reports 0.040 on real PeMS-4W)")

# Every execution engine produces the SAME integer codes (the paper's
# point: one parameterised design, many implementations).
for backend in ("ref", "pallas", "xla"):
    same = bool(jnp.all(acc.infer(x, path="int", backend=backend) == pred_hw))
    print(f"  backend {backend:6s}: bit-identical = {same}")

rep = acc.report()
print("\nAccelerator plan (Table 2 -> TPU):", rep["plan"])
print("Energy report (Table-4 analogue):", rep["energy"])
