"""Quickstart: the paper's pipeline end to end in ~a minute on CPU.

1. Build the paper's LSTM model (hidden 20, (4,8) fixed point, HardSigmoid*
   'step' + HardTanh).
2. QAT-train briefly on synthetic PeMS-like traffic data.
3. Quantise and run the deployment path — the fused Pallas kernel
   (interpret mode on CPU) — and check it matches the QAT model.
4. Print the Table-2 accelerator plan and the Table-4-style energy report.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import fixed_point as fxp
from repro.core.accelerator import AcceleratorConfig, PAPER_DEFAULT, plan
from repro.core.energy import power_report
from repro.core.qlstm import QLSTMConfig, ops_per_inference
from repro.data.timeseries import pems_like_dataset
from repro.models import lstm_model
from repro.training.optimizer import OptConfig, apply_updates, init_opt_state

cfg = QLSTMConfig()  # the paper's model
data = pems_like_dataset(seq_len=cfg.seq_len)
xtr, ytr = data["train"]
xte, yte = data["test"]

params = lstm_model.init_lstm_model(cfg, jax.random.key(0))[0]
opt_cfg = OptConfig(lr=3e-3, weight_decay=0.0, warmup_steps=10, total_steps=150)
opt = init_opt_state(params, opt_cfg)


@jax.jit
def step(params, opt, x, y):
    (l, _), g = jax.value_and_grad(
        lambda p: lstm_model.loss_fn(p, {"x": x, "y": y}, cfg, "qat"),
        has_aux=True)(params)
    params, opt, _ = apply_updates(params, g, opt, opt_cfg)
    return params, opt, l


import numpy as np
rng = np.random.default_rng(0)
for i in range(150):
    idx = rng.integers(0, len(xtr), 64)
    params, opt, l = step(params, opt, jnp.asarray(xtr[idx]), jnp.asarray(ytr[idx]))
    if i % 50 == 0:
        print(f"step {i:4d}  QAT loss {float(l):.5f}")

x = jnp.asarray(xte[:512])
y = jnp.asarray(yte[:512])
mse_qat = float(jnp.mean((lstm_model.forward(params, x, cfg, 'qat') - y) ** 2))
pred_hw = lstm_model.serve_int(params, x, cfg, PAPER_DEFAULT)   # Pallas kernel
mse_hw = float(jnp.mean((pred_hw - y) ** 2))
print(f"\ntest MSE: QAT={mse_qat:.5f}  int8-accelerator={mse_hw:.5f} "
      f"(paper reports 0.040 on real PeMS-4W)")

p = plan(cfg, PAPER_DEFAULT)
print("\nAccelerator plan (Table 2 -> TPU):", p)
ops = ops_per_inference(cfg)
lat = 28.07e-6  # paper latency; energy model maps it to TPU terms
print("Energy report (Table-4 analogue):",
      power_report(flops=ops, hbm_bytes=p['weight_bytes'], ici_bytes=0,
                   latency_s=lat, dtype='int8'))
