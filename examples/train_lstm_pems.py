"""The paper's §6.1 experiment, end to end (e2e training driver).

Drives the session API (``repro.build`` -> ``train_qat`` -> ``quantize``
-> ``infer``; docs/API.md) via ``launch/train.py``: QAT on PeMS-like
traffic data, then MSE for float / QAT / the bit-exact int8 accelerator
datapath.  Checkpoints land in /tmp/repro_lstm_ckpt — rerun to resume;
Ctrl-C checkpoints-and-exits (the fault-tolerance contract).

Run:  PYTHONPATH=src python examples/train_lstm_pems.py [--steps 400]
"""
import sys
sys.argv = [sys.argv[0], "--arch", "lstm-pems",
            "--ckpt-dir", "/tmp/repro_lstm_ckpt"] + sys.argv[1:]
from repro.launch.train import main
main()
